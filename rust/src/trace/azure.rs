//! Synthetic Azure-like LLM inference trace generator.
//!
//! Substitutes for the Splitwise production traces (see DESIGN.md). The
//! published Splitwise trace analysis reports, per workload:
//!
//! * **Conversation**: median prompt ≈ 1020 tokens, median output ≈ 129
//!   tokens, both heavy-tailed.
//! * **Coding**: median prompt ≈ 1930 tokens, median output ≈ 13–30 tokens
//!   (short completions).
//!
//! We model token counts as clamped log-normals matching those medians
//! with realistic tails, and arrivals as a Poisson process at the target
//! throughput — the x-axis of Figs. 2/6/7/8.

use super::{Request, Trace};
use crate::util::rng::Rng;

/// Which workload scenario to synthesize: a token-marginal mix plus an
/// arrival process. `Conversation`/`Coding`/`Mixed` are the Splitwise
/// marginals under homogeneous Poisson arrivals (the paper's §6.1.2
/// setup); `Diurnal`, `Bursty` and `LongContext` are the sweep engine's
/// additional stress scenarios (day/night cycles, Markov-modulated
/// on/off bursts, and long-context serving à la RAG/agentic traffic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    Conversation,
    Coding,
    /// Production-like blend: 70 % conversation, 30 % coding.
    Mixed,
    /// Mixed marginals under a sinusoidal (day/night) rate profile:
    /// `λ(t) = rate·(1 + A·sin(2πt/T))` with one full period per trace.
    Diurnal,
    /// Mixed marginals under a two-state Markov-modulated Poisson
    /// process: ON bursts well above the mean rate, quiet OFF valleys,
    /// time-averaging to the configured rate.
    Bursty,
    /// Long-context requests (multi-thousand-token prompts, long
    /// completions) under homogeneous Poisson arrivals.
    LongContext,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Workload, String> {
        match s {
            "conv" | "conversation" => Ok(Workload::Conversation),
            "code" | "coding" => Ok(Workload::Coding),
            "mixed" => Ok(Workload::Mixed),
            "diurnal" => Ok(Workload::Diurnal),
            "bursty" => Ok(Workload::Bursty),
            "long" | "long-context" | "longcontext" => Ok(Workload::LongContext),
            other => Err(format!(
                "unknown workload '{other}' (conv|code|mixed|diurnal|bursty|long-context)"
            )),
        }
    }

    /// Canonical name (accepted by [`Workload::parse`]); used by the sweep
    /// report and CSV/JSON output.
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Conversation => "conv",
            Workload::Coding => "code",
            Workload::Mixed => "mixed",
            Workload::Diurnal => "diurnal",
            Workload::Bursty => "bursty",
            Workload::LongContext => "long-context",
        }
    }
}

/// Every scenario, in sweep-axis order.
pub const ALL_WORKLOADS: [Workload; 6] = [
    Workload::Conversation,
    Workload::Coding,
    Workload::Mixed,
    Workload::Diurnal,
    Workload::Bursty,
    Workload::LongContext,
];

/// Generator parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceParams {
    /// Offered load in requests per second (cluster-wide).
    pub rate_rps: f64,
    /// Trace length in seconds.
    pub duration_s: f64,
    pub workload: Workload,
    pub seed: u64,
}

/// Log-normal spec in (median, sigma) form with clamping.
#[derive(Clone, Copy, Debug)]
struct TokenDist {
    median: f64,
    sigma: f64,
    min: u32,
    max: u32,
}

impl TokenDist {
    fn sample(&self, rng: &mut Rng) -> u32 {
        let mu = self.median.ln();
        let x = rng.lognormal(mu, self.sigma);
        (x.round() as u32).clamp(self.min, self.max)
    }
}

const CONV_PROMPT: TokenDist = TokenDist { median: 1020.0, sigma: 1.0, min: 4, max: 8192 };
const CONV_OUTPUT: TokenDist = TokenDist { median: 129.0, sigma: 0.8, min: 1, max: 1024 };
const CODE_PROMPT: TokenDist = TokenDist { median: 1930.0, sigma: 0.7, min: 16, max: 8192 };
const CODE_OUTPUT: TokenDist = TokenDist { median: 28.0, sigma: 0.9, min: 1, max: 512 };
// Long-context serving (RAG / agentic traffic): prompts an order of
// magnitude above conversation, with long completions.
const LONG_PROMPT: TokenDist = TokenDist { median: 6000.0, sigma: 0.5, min: 256, max: 32768 };
const LONG_OUTPUT: TokenDist = TokenDist { median: 512.0, sigma: 0.6, min: 16, max: 4096 };

/// Diurnal default amplitude used when the scenario is selected via
/// [`Workload::Diurnal`] (the explicit [`AzureTraceGen::generate_diurnal`]
/// entry point still takes the amplitude as a parameter).
pub const DIURNAL_AMPLITUDE: f64 = 0.6;

/// Bursty (MMPP) defaults: the process spends [`BURSTY_ON_FRACTION`] of
/// time in the ON state (mean sojourn [`BURSTY_MEAN_ON_S`] seconds), and
/// the OFF-state rate is [`BURSTY_OFF_RATE_FRACTION`] of the mean rate;
/// the ON rate is derived so the time-average equals the configured rate.
pub const BURSTY_ON_FRACTION: f64 = 0.3;
pub const BURSTY_OFF_RATE_FRACTION: f64 = 0.2;
pub const BURSTY_MEAN_ON_S: f64 = 2.0;

/// The trace generator.
pub struct AzureTraceGen {
    pub params: TraceParams,
}

/// Sample one request's `(prompt_tokens, output_tokens)` for a scenario.
/// The arrival-process scenarios (`Diurnal`, `Bursty`) use the `Mixed`
/// marginals; the draw order is identical to the original generator so
/// pre-existing seeds reproduce byte-identical conv/code/mixed traces.
fn sample_tokens(workload: Workload, rng: &mut Rng) -> (u32, u32) {
    let coding = match workload {
        Workload::Conversation => false,
        Workload::Coding => true,
        Workload::Mixed | Workload::Diurnal | Workload::Bursty => rng.bool(0.3),
        Workload::LongContext => {
            return (LONG_PROMPT.sample(rng), LONG_OUTPUT.sample(rng));
        }
    };
    if coding {
        (CODE_PROMPT.sample(rng), CODE_OUTPUT.sample(rng))
    } else {
        (CONV_PROMPT.sample(rng), CONV_OUTPUT.sample(rng))
    }
}

impl AzureTraceGen {
    pub fn new(params: TraceParams) -> AzureTraceGen {
        AzureTraceGen { params }
    }

    /// Generate a trace with a diurnal load profile: an inhomogeneous
    /// Poisson process `λ(t) = rate·(1 + amplitude·sin(2πt/period))`
    /// sampled by thinning. Production Azure traffic follows day/night
    /// cycles; this stresses Selective Core Idling's tracking of load
    /// *decreases* (the periodic branch of the controller).
    pub fn generate_diurnal(&self, amplitude: f64, period_s: f64) -> Trace {
        assert!((0.0..=1.0).contains(&amplitude), "amplitude in [0,1]");
        assert!(period_s > 0.0);
        let p = &self.params;
        let mut rng = Rng::new(p.seed ^ 0xD1_0C);
        let lambda_max = p.rate_rps * (1.0 + amplitude);
        let mut requests = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += rng.exp(lambda_max);
            if t >= p.duration_s {
                break;
            }
            let lambda_t = p.rate_rps
                * (1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_s).sin());
            if !rng.bool(lambda_t / lambda_max) {
                continue; // thinned
            }
            let (pt, ot) = sample_tokens(p.workload, &mut rng);
            requests.push(Request { id, arrival_s: t, prompt_tokens: pt, output_tokens: ot });
            id += 1;
        }
        Trace { requests, duration_s: p.duration_s }
    }

    /// Generate a trace with a two-state Markov-modulated Poisson arrival
    /// process (EcoServe-style bursty demand). The chain alternates
    /// between an ON state (mean sojourn `mean_on_s`, arrival rate well
    /// above the mean) and an OFF state (rate `off_rate_frac · rate`),
    /// with sojourn times exponential and rates chosen so the
    /// time-average equals `rate_rps`:
    ///
    /// `λ_on = (1 − (1−d)·off_rate_frac) / d · rate`, `d = on_fraction`.
    pub fn generate_bursty(&self, on_fraction: f64, off_rate_frac: f64, mean_on_s: f64) -> Trace {
        assert!((0.0..1.0).contains(&on_fraction) && on_fraction > 0.0, "on_fraction in (0,1)");
        assert!((0.0..1.0).contains(&off_rate_frac), "off_rate_frac in [0,1)");
        assert!(mean_on_s > 0.0);
        let p = &self.params;
        let mut rng = Rng::new(p.seed ^ 0xB0_57);
        let lambda_off = off_rate_frac * p.rate_rps;
        let lambda_on = (1.0 - (1.0 - on_fraction) * off_rate_frac) / on_fraction * p.rate_rps;
        let mean_off_s = mean_on_s * (1.0 - on_fraction) / on_fraction;
        let mut requests = Vec::new();
        let mut id = 0u64;
        let mut t = 0.0;
        let mut on = rng.bool(on_fraction); // start in steady state
        while t < p.duration_s {
            let sojourn = rng.exp(1.0 / if on { mean_on_s } else { mean_off_s });
            let state_end = (t + sojourn).min(p.duration_s);
            let lambda = if on { lambda_on } else { lambda_off };
            if lambda > 0.0 {
                let mut at = t;
                loop {
                    at += rng.exp(lambda);
                    if at >= state_end {
                        break;
                    }
                    let (pt, ot) = sample_tokens(p.workload, &mut rng);
                    requests.push(Request {
                        id,
                        arrival_s: at,
                        prompt_tokens: pt,
                        output_tokens: ot,
                    });
                    id += 1;
                }
            }
            t = state_end;
            on = !on;
        }
        Trace { requests, duration_s: p.duration_s }
    }

    /// Generate a homogeneous-Poisson trace (the original §6.1.2 process).
    fn generate_poisson(&self) -> Trace {
        let mut rng = Rng::new(self.params.seed);
        let mut requests = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += rng.exp(self.params.rate_rps);
            if t >= self.params.duration_s {
                break;
            }
            let (p, o) = sample_tokens(self.params.workload, &mut rng);
            requests.push(Request { id, arrival_s: t, prompt_tokens: p, output_tokens: o });
            id += 1;
        }
        Trace { requests, duration_s: self.params.duration_s }
    }

    /// Generate a full trace, dispatching on the scenario's arrival
    /// process: homogeneous Poisson for `conv`/`code`/`mixed`/`long-context`,
    /// one sinusoidal period over the trace for `diurnal` (amplitude
    /// [`DIURNAL_AMPLITUDE`]), and the MMPP defaults for `bursty`.
    pub fn generate(&self) -> Trace {
        match self.params.workload {
            Workload::Diurnal => {
                self.generate_diurnal(DIURNAL_AMPLITUDE, self.params.duration_s)
            }
            Workload::Bursty => self.generate_bursty(
                BURSTY_ON_FRACTION,
                BURSTY_OFF_RATE_FRACTION,
                BURSTY_MEAN_ON_S,
            ),
            _ => self.generate_poisson(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn gen(rate: f64, dur: f64, w: Workload, seed: u64) -> Trace {
        AzureTraceGen::new(TraceParams { rate_rps: rate, duration_s: dur, workload: w, seed })
            .generate()
    }

    #[test]
    fn rate_matches_target() {
        let t = gen(60.0, 300.0, Workload::Mixed, 1);
        assert!((t.rate_rps() - 60.0).abs() < 3.0, "rate={}", t.rate_rps());
        assert!(t.validate().is_ok());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen(40.0, 60.0, Workload::Mixed, 7);
        let b = gen(40.0, 60.0, Workload::Mixed, 7);
        assert_eq!(a.requests, b.requests);
        let c = gen(40.0, 60.0, Workload::Mixed, 8);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn conv_medians_match_published_stats() {
        let t = gen(200.0, 300.0, Workload::Conversation, 2);
        let prompts: Vec<f64> = t.requests.iter().map(|r| r.prompt_tokens as f64).collect();
        let outputs: Vec<f64> = t.requests.iter().map(|r| r.output_tokens as f64).collect();
        let p50_p = stats::percentile(&prompts, 50.0);
        let p50_o = stats::percentile(&outputs, 50.0);
        assert!((p50_p - 1020.0).abs() < 150.0, "prompt median={p50_p}");
        assert!((p50_o - 129.0).abs() < 25.0, "output median={p50_o}");
    }

    #[test]
    fn coding_outputs_are_short() {
        let t = gen(200.0, 200.0, Workload::Coding, 3);
        let outputs: Vec<f64> = t.requests.iter().map(|r| r.output_tokens as f64).collect();
        let p50 = stats::percentile(&outputs, 50.0);
        assert!(p50 < 60.0, "coding output median={p50}");
        let prompts: Vec<f64> = t.requests.iter().map(|r| r.prompt_tokens as f64).collect();
        assert!(stats::percentile(&prompts, 50.0) > 1500.0);
    }

    #[test]
    fn interarrivals_are_exponential() {
        let t = gen(100.0, 200.0, Workload::Mixed, 4);
        let gaps: Vec<f64> =
            t.requests.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
        let mean_gap = stats::mean(&gaps);
        // Poisson(100/s) -> mean gap 10 ms; CV of exponential = 1.
        assert!((mean_gap - 0.01).abs() < 0.002, "mean gap={mean_gap}");
        let cv = stats::coeff_of_variation(&gaps);
        assert!((cv - 1.0).abs() < 0.12, "cv={cv}");
    }

    #[test]
    fn diurnal_profile_modulates_rate() {
        let g = AzureTraceGen::new(TraceParams {
            rate_rps: 100.0,
            duration_s: 400.0,
            workload: Workload::Mixed,
            seed: 6,
        });
        // One full sine period: first half above base rate, second below.
        let t = g.generate_diurnal(0.8, 400.0);
        assert!(t.validate().is_ok());
        let first = t.requests.iter().filter(|r| r.arrival_s < 200.0).count() as f64;
        let second = t.requests.len() as f64 - first;
        assert!(first > second * 1.8, "first={first} second={second}");
        // Total volume stays near the base rate (sine integrates to 0).
        assert!((t.rate_rps() - 100.0).abs() < 8.0, "rate={}", t.rate_rps());
    }

    #[test]
    fn diurnal_zero_amplitude_is_homogeneous() {
        let g = AzureTraceGen::new(TraceParams {
            rate_rps: 50.0,
            duration_s: 100.0,
            workload: Workload::Mixed,
            seed: 8,
        });
        let t = g.generate_diurnal(0.0, 100.0);
        assert!((t.rate_rps() - 50.0).abs() < 5.0);
        let first = t.requests.iter().filter(|r| r.arrival_s < 50.0).count() as f64;
        let second = t.requests.len() as f64 - first;
        assert!((first / second - 1.0).abs() < 0.25);
    }

    #[test]
    fn parse_knows_every_scenario() {
        for w in ALL_WORKLOADS {
            assert_eq!(Workload::parse(w.name()).unwrap(), w);
        }
        assert_eq!(Workload::parse("long").unwrap(), Workload::LongContext);
        assert!(Workload::parse("nope").is_err());
    }

    #[test]
    fn diurnal_scenario_flows_through_generate() {
        let t = gen(80.0, 400.0, Workload::Diurnal, 11);
        assert!(t.validate().is_ok());
        // One sine period over the trace: front-loaded arrivals, mean
        // rate near the configured target.
        let first = t.requests.iter().filter(|r| r.arrival_s < 200.0).count() as f64;
        let second = t.requests.len() as f64 - first;
        assert!(first > second * 1.5, "first={first} second={second}");
        assert!((t.rate_rps() - 80.0).abs() < 8.0, "rate={}", t.rate_rps());
    }

    #[test]
    fn bursty_scenario_matches_mean_rate_and_bursts() {
        let t = gen(60.0, 600.0, Workload::Bursty, 12);
        assert!(t.validate().is_ok());
        assert!((t.rate_rps() - 60.0).abs() < 12.0, "rate={}", t.rate_rps());
        // MMPP interarrivals are overdispersed relative to Poisson:
        // coefficient of variation well above 1.
        let gaps: Vec<f64> =
            t.requests.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
        let cv = stats::coeff_of_variation(&gaps);
        assert!(cv > 1.15, "bursty interarrival cv={cv} not overdispersed");
    }

    #[test]
    fn bursty_is_deterministic_per_seed() {
        let a = gen(40.0, 120.0, Workload::Bursty, 9);
        let b = gen(40.0, 120.0, Workload::Bursty, 9);
        assert_eq!(a.requests, b.requests);
        let c = gen(40.0, 120.0, Workload::Bursty, 10);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn long_context_has_long_prompts_and_outputs() {
        let t = gen(100.0, 200.0, Workload::LongContext, 13);
        assert!(t.validate().is_ok());
        let prompts: Vec<f64> = t.requests.iter().map(|r| r.prompt_tokens as f64).collect();
        let outputs: Vec<f64> = t.requests.iter().map(|r| r.output_tokens as f64).collect();
        assert!((stats::percentile(&prompts, 50.0) - 6000.0).abs() < 900.0);
        assert!(stats::percentile(&outputs, 50.0) > 300.0);
        for r in &t.requests {
            assert!((256..=32768).contains(&r.prompt_tokens));
            assert!((16..=4096).contains(&r.output_tokens));
        }
    }

    #[test]
    fn tokens_within_clamps() {
        let t = gen(100.0, 100.0, Workload::Mixed, 5);
        for r in &t.requests {
            assert!((1..=8192).contains(&r.prompt_tokens));
            assert!((1..=1024).contains(&r.output_tokens));
        }
    }
}
