//! A minimal, dependency-free Rust lexer for the `simlint` pass.
//!
//! Same offline philosophy as `util/json.rs`: no proc-macro crates, no
//! `syn` — just enough tokenization that the rules in
//! [`super::rules`] can tell *code* apart from comments and string
//! literals. A grep-based lint would flag `partial_cmp` inside a doc
//! comment or a string constant; this lexer never does, because rules
//! only ever see the comment-free token stream.
//!
//! What it understands (everything the rules need, nothing more):
//!
//! * line comments (`//`, `///`, `//!`) — kept in the stream so the
//!   pragma scanner can read `// simlint: allow(..) -- reason`;
//! * block comments, **nested** (`/* /* */ */`), possibly multi-line;
//! * string literals with escapes, byte strings (`b"…"`), and raw /
//!   raw-byte strings with any hash depth (`r#"…"#`, `br##"…"##`);
//! * char and byte-char literals (`'x'`, `'\n'`, `b'\0'`) vs
//!   lifetimes (`'a`, `'static`) — the classic single-quote ambiguity;
//! * raw identifiers (`r#match`), plain identifiers, numbers (with
//!   type suffixes, and `5.into()` lexing as `5` `.` `into` exactly
//!   like rustc), and single-character punctuation.
//!
//! The lexer is intentionally forgiving: an unterminated literal at
//! EOF simply ends the token rather than erroring, because the input
//! is the repo's own source (which must already compile to reach CI)
//! and lint fixtures (which need not compile at all).

/// Token class. Rules match on `(kind, text)` pairs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`partial_cmp`, `for`, `in`, `spawn`, …).
    Ident,
    /// Numeric literal (`5`, `0xBE7C`, `1e-9`, `2.5f64`).
    Number,
    /// String literal of any flavour; `text` is the *content* (no
    /// quotes, no prefix), so the schema rule can compare it directly.
    Str,
    /// Char or byte-char literal; content without quotes.
    Char,
    /// Lifetime (`'a`); content without the leading quote.
    Lifetime,
    /// Single punctuation character (`.`, `:`, `(`, `{`, `&`, …).
    Punct,
    /// `// …` comment, full text including the slashes (pragmas).
    LineComment,
    /// `/* … */` comment, full text; may span lines.
    BlockComment,
}

/// One lexed token with the 1-indexed source line it starts on.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

struct Cursor {
    chars: Vec<char>,
    pos: usize,
    line: usize,
}

impl Cursor {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn peek_at(&self, off: usize) -> Option<char> {
        self.chars.get(self.pos + off).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into a token stream. Never fails: unknown characters
/// become single-char [`TokKind::Punct`] tokens and unterminated
/// literals end at EOF.
pub fn lex(src: &str) -> Vec<Tok> {
    let mut cur = Cursor { chars: src.chars().collect(), pos: 0, line: 1 };
    let mut toks = Vec::new();
    while let Some(c) = cur.peek() {
        let line = cur.line;
        if c.is_whitespace() {
            cur.bump();
            continue;
        }
        if c == '/' && cur.peek_at(1) == Some('/') {
            toks.push(Tok { kind: TokKind::LineComment, text: line_comment(&mut cur), line });
            continue;
        }
        if c == '/' && cur.peek_at(1) == Some('*') {
            toks.push(Tok { kind: TokKind::BlockComment, text: block_comment(&mut cur), line });
            continue;
        }
        if c == '"' {
            toks.push(Tok { kind: TokKind::Str, text: cooked_string(&mut cur), line });
            continue;
        }
        // `r"…"`, `r#"…"#`, `r#ident` — raw string vs raw identifier.
        if c == 'r' {
            if let Some(hashes) = raw_string_hashes(&cur, 1) {
                toks.push(Tok { kind: TokKind::Str, text: raw_string(&mut cur, 1, hashes), line });
                continue;
            }
            if cur.peek_at(1) == Some('#') && cur.peek_at(2).is_some_and(is_ident_start) {
                cur.bump(); // r
                cur.bump(); // #
                toks.push(Tok { kind: TokKind::Ident, text: ident(&mut cur), line });
                continue;
            }
        }
        // `b"…"`, `br#"…"#`, `b'…'` — byte-literal prefixes.
        if c == 'b' {
            if cur.peek_at(1) == Some('"') {
                cur.bump(); // b
                toks.push(Tok { kind: TokKind::Str, text: cooked_string(&mut cur), line });
                continue;
            }
            if cur.peek_at(1) == Some('r') {
                if let Some(hashes) = raw_string_hashes(&cur, 2) {
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text: raw_string(&mut cur, 2, hashes),
                        line,
                    });
                    continue;
                }
            }
            if cur.peek_at(1) == Some('\'') {
                cur.bump(); // b
                toks.push(Tok { kind: TokKind::Char, text: char_literal(&mut cur), line });
                continue;
            }
        }
        if c == '\'' {
            // Lifetime unless it closes as a char literal: `'\…'` and
            // `'x'` are chars; `'a` / `'static` (no closing quote after
            // the first ident char run) are lifetimes.
            let is_char = cur.peek_at(1) == Some('\\')
                || (cur.peek_at(1).is_some() && cur.peek_at(2) == Some('\''));
            if is_char {
                toks.push(Tok { kind: TokKind::Char, text: char_literal(&mut cur), line });
            } else {
                cur.bump(); // '
                toks.push(Tok { kind: TokKind::Lifetime, text: ident(&mut cur), line });
            }
            continue;
        }
        if is_ident_start(c) {
            toks.push(Tok { kind: TokKind::Ident, text: ident(&mut cur), line });
            continue;
        }
        if c.is_ascii_digit() {
            toks.push(Tok { kind: TokKind::Number, text: number(&mut cur), line });
            continue;
        }
        cur.bump();
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
    }
    toks
}

fn line_comment(cur: &mut Cursor) -> String {
    let mut s = String::new();
    while let Some(c) = cur.peek() {
        if c == '\n' {
            break;
        }
        s.push(c);
        cur.bump();
    }
    s
}

fn block_comment(cur: &mut Cursor) -> String {
    let mut s = String::new();
    let mut depth = 0usize;
    while let Some(c) = cur.peek() {
        if c == '/' && cur.peek_at(1) == Some('*') {
            depth += 1;
            s.push_str("/*");
            cur.bump();
            cur.bump();
            continue;
        }
        if c == '*' && cur.peek_at(1) == Some('/') {
            depth -= 1;
            s.push_str("*/");
            cur.bump();
            cur.bump();
            if depth == 0 {
                break;
            }
            continue;
        }
        s.push(c);
        cur.bump();
    }
    s
}

/// Consume a `"…"` body (opening quote under the cursor); returns the
/// content with escape sequences left verbatim.
fn cooked_string(cur: &mut Cursor) -> String {
    let mut s = String::new();
    cur.bump(); // opening "
    while let Some(c) = cur.bump() {
        if c == '\\' {
            s.push(c);
            if let Some(e) = cur.bump() {
                s.push(e);
            }
            continue;
        }
        if c == '"' {
            break;
        }
        s.push(c);
    }
    s
}

/// If the cursor sits on a raw-string opener at `prefix_len` chars in
/// (`r` = 1, `br` = 2), return its hash count.
fn raw_string_hashes(cur: &Cursor, prefix_len: usize) -> Option<usize> {
    let mut n = 0;
    while cur.peek_at(prefix_len + n) == Some('#') {
        n += 1;
    }
    (cur.peek_at(prefix_len + n) == Some('"')).then_some(n)
}

fn raw_string(cur: &mut Cursor, prefix_len: usize, hashes: usize) -> String {
    for _ in 0..prefix_len + hashes + 1 {
        cur.bump(); // prefix, hashes, opening quote
    }
    let mut s = String::new();
    while let Some(c) = cur.bump() {
        if c == '"' {
            let closed = (0..hashes).all(|i| cur.peek_at(i) == Some('#'));
            if closed {
                for _ in 0..hashes {
                    cur.bump();
                }
                break;
            }
        }
        s.push(c);
    }
    s
}

fn char_literal(cur: &mut Cursor) -> String {
    let mut s = String::new();
    cur.bump(); // opening '
    while let Some(c) = cur.bump() {
        if c == '\\' {
            s.push(c);
            if let Some(e) = cur.bump() {
                s.push(e);
            }
            continue;
        }
        if c == '\'' {
            break;
        }
        s.push(c);
    }
    s
}

fn ident(cur: &mut Cursor) -> String {
    let mut s = String::new();
    while let Some(c) = cur.peek() {
        if !is_ident_continue(c) {
            break;
        }
        s.push(c);
        cur.bump();
    }
    s
}

/// Numbers: digits, `_`, hex/suffix letters; a `.` joins only when a
/// digit follows, so `5.into()` lexes as `5` `.` `into` — exactly the
/// boundary the schema-version rule relies on. `1e-9` keeps its sign.
fn number(cur: &mut Cursor) -> String {
    let mut s = String::new();
    while let Some(c) = cur.peek() {
        if c.is_ascii_alphanumeric() || c == '_' {
            s.push(c);
            cur.bump();
            // Exponent sign: `1e-9`, `2E+5`.
            if (c == 'e' || c == 'E')
                && matches!(cur.peek(), Some('+') | Some('-'))
                && cur.peek_at(1).is_some_and(|d| d.is_ascii_digit())
            {
                s.push(cur.bump().unwrap());
            }
            continue;
        }
        if c == '.' && cur.peek_at(1).is_some_and(|d| d.is_ascii_digit()) {
            s.push(c);
            cur.bump();
            continue;
        }
        break;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_are_isolated_from_code() {
        let toks = kinds("a.partial_cmp(b) // a.partial_cmp(b)\n/* partial_cmp */ x");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, ["a", "partial_cmp", "b", "x"]);
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::LineComment).count(), 1);
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::BlockComment).count(), 1);
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("/* outer /* inner */ still comment */ code");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1], (TokKind::Ident, "code".into()));
    }

    #[test]
    fn strings_hide_their_content_from_code() {
        let toks = kinds(r#"let s = "Instant::now() inside a string";"#);
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Str && t.contains("Instant")));
        let idents: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, ["let", "s"]);
    }

    #[test]
    fn raw_and_byte_strings() {
        let toks = kinds(r###"r#"raw "quoted" body"# b"bytes" br##"deep"##"###);
        let strs: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(strs, [r#"raw "quoted" body"#, "bytes", "deep"]);
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        let toks = kinds(r#""a \" b" tail"#);
        assert_eq!(toks[0], (TokKind::Str, r#"a \" b"#.into()));
        assert_eq!(toks[1], (TokKind::Ident, "tail".into()));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds(r"fn f<'a>(x: &'a str) { let c = 'x'; let n = '\n'; }");
        let lifetimes = toks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count();
        let chars = toks.iter().filter(|(k, _)| *k == TokKind::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn number_then_dot_method_splits_like_rustc() {
        let toks = kinds("5.into() 2.5f64 0xBE7C 1e-9");
        assert_eq!(toks[0], (TokKind::Number, "5".into()));
        assert_eq!(toks[1], (TokKind::Punct, ".".into()));
        assert_eq!(toks[2], (TokKind::Ident, "into".into()));
        assert_eq!(toks[5], (TokKind::Number, "2.5f64".into()));
        assert_eq!(toks[6], (TokKind::Number, "0xBE7C".into()));
        assert_eq!(toks[7], (TokKind::Number, "1e-9".into()));
    }

    #[test]
    fn raw_identifiers() {
        let toks = kinds("r#match r#fn");
        assert_eq!(toks[0], (TokKind::Ident, "match".into()));
        assert_eq!(toks[1], (TokKind::Ident, "fn".into()));
    }

    #[test]
    fn line_numbers_are_one_indexed_and_track_newlines() {
        let toks = lex("a\nb\n/* c\nd */\ne");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[2].line, 3); // block comment starts on line 3
        assert_eq!(toks[3].line, 5); // `e` after the two-line comment
    }

    #[test]
    fn unterminated_string_ends_at_eof() {
        let toks = kinds("\"never closed");
        assert_eq!(toks, vec![(TokKind::Str, "never closed".into())]);
    }
}
