//! `simlint` — the repo's determinism & invariants static-analysis
//! pass (`carbon-sim lint`).
//!
//! Every headline number this crate reproduces rests on byte-identity
//! contracts (same sweep report at any `--threads`, shard count,
//! `--queue` kind, or resume point). Those contracts in turn rest on
//! coding rules that, before this pass, were tribal knowledge: float
//! sorts must use `total_cmp`, hash containers must never be iterated
//! on a result path, the simulator core must never read the wall
//! clock, concurrency must flow through the sanctioned layers, and
//! every `schema_version` stamp must come from
//! [`crate::experiments::OUTPUT_SCHEMA_VERSION`]. This module makes
//! them machine-checked: a dependency-free scanner (hand-rolled
//! [`lexer`], same offline philosophy as `util/json.rs`) walks the
//! source tree and reports named, `file:line`-addressed findings.
//!
//! * [`lexer`] — comment- and string-literal-aware tokenizer.
//! * [`rules`] — the five named rules and their allowlists.
//! * this module — file walking, pragma suppression, the [`LintReport`]
//!   (text and schema-versioned `lint-report` JSON), and the library
//!   API the CLI and tests drive.
//!
//! # Suppression pragma
//!
//! ```text
//! // simlint: allow(no-wall-clock) -- measuring the demo's own latency
//! ```
//!
//! A pragma suppresses the named rule(s) on its own line **and the
//! line below it** (so it can sit above the flagged statement). The
//! reason after ` -- ` is mandatory and the rule names must exist —
//! a malformed pragma is itself a finding (rule `simlint-pragma`) and
//! suppresses nothing. See `docs/static-analysis.md` for the full
//! contract each rule protects and how to add a rule.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Value;

pub mod lexer;
pub mod rules;

use lexer::{Tok, TokKind};
use rules::SchemaDef;

/// Rule name reserved for malformed suppression pragmas.
pub const RULE_PRAGMA: &str = "simlint-pragma";

/// One lint finding, addressed as `path:line` in rule `rule`.
#[derive(Clone, Debug)]
pub struct Finding {
    /// The violated rule (one of [`rules::RULE_NAMES`] or
    /// [`RULE_PRAGMA`]).
    pub rule: &'static str,
    /// `/`-normalized path as scanned (relative to the lint root's
    /// parent, e.g. `src/policy/proposed.rs`).
    pub path: String,
    /// 1-indexed source line.
    pub line: usize,
    /// Human-readable explanation with the suggested fix.
    pub message: String,
}

/// The result of one lint run: findings sorted by `(path, line, rule)`
/// plus the scan size, renderable as text or as the `lint-report` JSON
/// document (`docs/output-schemas.md` §6).
#[derive(Clone, Debug)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl LintReport {
    /// True when the scanned tree is violation-free (exit code 0).
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// One `path:line: [rule] message` line per finding plus a summary
    /// tail line; stable across runs (findings are sorted).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&format!("{}:{}: [{}] {}\n", f.path, f.line, f.rule, f.message));
        }
        if self.is_clean() {
            s.push_str(&format!(
                "simlint: clean — {} files scanned, {} rules, 0 findings\n",
                self.files_scanned,
                rules::RULE_NAMES.len()
            ));
        } else {
            s.push_str(&format!(
                "simlint: {} finding(s) in {} files scanned\n",
                self.findings.len(),
                self.files_scanned
            ));
        }
        s
    }

    /// The machine-readable `lint-report` document, stamped with
    /// [`crate::experiments::OUTPUT_SCHEMA_VERSION`] like every other
    /// output this crate emits.
    pub fn to_json(&self) -> Value {
        let findings: Vec<Value> = self
            .findings
            .iter()
            .map(|f| {
                Value::obj(vec![
                    ("rule", f.rule.into()),
                    ("path", f.path.as_str().into()),
                    ("line", f.line.into()),
                    ("message", f.message.as_str().into()),
                ])
            })
            .collect();
        Value::obj(vec![
            ("kind", "lint-report".into()),
            ("schema_version", crate::experiments::OUTPUT_SCHEMA_VERSION.into()),
            ("files_scanned", self.files_scanned.into()),
            ("clean", self.is_clean().into()),
            ("findings", Value::Arr(findings)),
        ])
    }
}

/// The default scan roots when the CLI gets no path arguments: the
/// crate's source tree, probed as `rust/src` (repo root, the CI working
/// directory) then `src` (package root, the `cargo test` working
/// directory).
pub fn default_roots() -> Result<Vec<PathBuf>, String> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Ok(vec![p]);
        }
    }
    Err("no rust/src or src directory under the working directory; pass paths to scan".to_string())
}

/// Lint `.rs` files under `roots` (files are taken as-is, directories
/// are walked recursively in sorted order, so the report is
/// deterministic). IO failures are hard errors, not findings: a
/// vanished file means the scan itself is wrong.
pub fn lint_tree(roots: &[PathBuf]) -> Result<LintReport, String> {
    let mut files = Vec::new();
    for root in roots {
        collect_rs_files(root, &mut files)?;
    }
    let mut findings = Vec::new();
    let mut schema_def: Option<SchemaDef> = None;
    for path in &files {
        let src = fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let rel = normalize(path);
        let toks = lexer::lex(&src);
        let pragmas = Pragmas::collect(&toks, &rel, &mut findings);
        let (file_findings, def) = rules::check_file(&rel, &toks);
        if def.is_some() {
            schema_def = def;
        }
        findings.extend(file_findings.into_iter().filter(|f| !pragmas.suppresses(f)));
    }
    if let Some(def) = &schema_def {
        check_docs_mention(def, &mut findings);
    }
    fn key(f: &Finding) -> (&str, usize, &str) {
        (f.path.as_str(), f.line, f.rule)
    }
    findings.sort_by(|a, b| key(a).cmp(&key(b)));
    Ok(LintReport { findings, files_scanned: files.len() })
}

/// `schema-version-sync`, docs half: `docs/output-schemas.md` (probed
/// relative to the working directory, repo root or package root) must
/// mention the version the scanned tree defines, as the literal phrase
/// `schema_version N`.
fn check_docs_mention(def: &SchemaDef, findings: &mut Vec<Finding>) {
    let doc_path = ["docs/output-schemas.md", "../docs/output-schemas.md"]
        .iter()
        .map(Path::new)
        .find(|p| p.is_file());
    let Some(doc_path) = doc_path else {
        let msg = "docs/output-schemas.md not found next to the scanned tree; the schema \
                   document must ship with the code that stamps the version";
        findings.push(Finding {
            rule: rules::RULE_SCHEMA_VERSION_SYNC,
            path: def.path.clone(),
            line: def.line,
            message: msg.to_string(),
        });
        return;
    };
    let doc = fs::read_to_string(doc_path).unwrap_or_default();
    let phrase = format!("schema_version {}", def.version);
    if !doc.contains(&phrase) {
        findings.push(Finding {
            rule: rules::RULE_SCHEMA_VERSION_SYNC,
            path: def.path.clone(),
            line: def.line,
            message: format!(
                "OUTPUT_SCHEMA_VERSION is {} but docs/output-schemas.md never says \
                 `{phrase}` — update the schema document in the same change that bumps \
                 the constant",
                def.version
            ),
        });
    }
}

fn normalize(path: &Path) -> String {
    path.to_string_lossy().replace('\\', "/")
}

fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    if !root.is_dir() {
        return Err(format!("lint path {} is neither a file nor a directory", root.display()));
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(root)
        .map_err(|e| format!("reading {}: {e}", root.display()))?
        .map(|r| r.map(|e| e.path()))
        .collect::<Result<_, _>>()
        .map_err(|e| format!("reading {}: {e}", root.display()))?;
    entries.sort();
    for entry in entries {
        if entry.is_dir() {
            collect_rs_files(&entry, out)?;
        } else if entry.extension().is_some_and(|x| x == "rs") {
            out.push(entry);
        }
    }
    Ok(())
}

/// Parsed suppression pragmas of one file: rule name → suppressed
/// lines. A pragma at line L covers L and L+1.
struct Pragmas {
    covered: BTreeMap<&'static str, Vec<usize>>,
}

impl Pragmas {
    /// Scan the full token stream (comments included) for
    /// `// simlint: allow(rule, …) -- reason` directives; malformed
    /// directives become findings under [`RULE_PRAGMA`] and suppress
    /// nothing.
    fn collect(toks: &[Tok], rel: &str, findings: &mut Vec<Finding>) -> Pragmas {
        let mut covered: BTreeMap<&'static str, Vec<usize>> = BTreeMap::new();
        for t in toks {
            if t.kind != TokKind::LineComment {
                continue;
            }
            let body = t.text.trim_start_matches('/').trim();
            let Some(directive) = body.strip_prefix("simlint:") else { continue };
            match parse_pragma(directive.trim()) {
                Ok(names) => {
                    for name in names {
                        covered.entry(name).or_default().extend([t.line, t.line + 1]);
                    }
                }
                Err(msg) => findings.push(Finding {
                    rule: RULE_PRAGMA,
                    path: rel.to_string(),
                    line: t.line,
                    message: msg,
                }),
            }
        }
        Pragmas { covered }
    }

    fn suppresses(&self, f: &Finding) -> bool {
        self.covered.get(f.rule).is_some_and(|lines| lines.contains(&f.line))
    }
}

/// Parse the directive after `simlint:`. Grammar:
/// `allow(<rule>[, <rule>]*) -- <non-empty reason>`.
fn parse_pragma(directive: &str) -> Result<Vec<&'static str>, String> {
    let Some(rest) = directive.strip_prefix("allow(") else {
        return Err(format!(
            "malformed simlint pragma `{directive}`: expected `allow(<rule>) -- <reason>`"
        ));
    };
    let Some((inside, tail)) = rest.split_once(')') else {
        return Err("malformed simlint pragma: unclosed `allow(`".to_string());
    };
    let tail = tail.trim();
    let reason = tail.strip_prefix("--").map(str::trim).unwrap_or("");
    if !tail.starts_with("--") || reason.is_empty() {
        return Err("simlint pragma missing ` -- <reason>` (the reason is mandatory)".to_string());
    }
    let mut names = Vec::new();
    for raw in inside.split(',') {
        let raw = raw.trim();
        match rules::RULE_NAMES.iter().find(|n| **n == raw) {
            Some(name) => names.push(*name),
            None => {
                return Err(format!(
                    "simlint pragma names unknown rule `{raw}` (known: {})",
                    rules::RULE_NAMES.join(", ")
                ));
            }
        }
    }
    if names.is_empty() {
        return Err("simlint pragma allows no rules: name at least one".to_string());
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_src(rel: &str, src: &str) -> Vec<Finding> {
        let toks = lexer::lex(src);
        let mut findings = Vec::new();
        let pragmas = Pragmas::collect(&toks, rel, &mut findings);
        let (file_findings, _) = rules::check_file(rel, &toks);
        findings.extend(file_findings.into_iter().filter(|f| !pragmas.suppresses(f)));
        findings
    }

    #[test]
    fn pragma_on_line_above_suppresses() {
        let src = "fn f() {\n\
                   // simlint: allow(no-wall-clock) -- test fixture timing its own harness\n\
                   let t = std::time::Instant::now();\n\
                   }\n";
        assert!(lint_src("src/x.rs", src).is_empty());
    }

    #[test]
    fn pragma_on_same_line_suppresses() {
        let src = "let t = std::time::Instant::now(); \
                   // simlint: allow(no-wall-clock) -- demo latency probe\n";
        assert!(lint_src("src/x.rs", src).is_empty());
    }

    #[test]
    fn pragma_does_not_leak_past_the_next_line() {
        let src = "// simlint: allow(no-wall-clock) -- only covers the next line\n\
                   let a = 1;\n\
                   let t = std::time::Instant::now();\n";
        let found = lint_src("src/x.rs", src);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, rules::RULE_NO_WALL_CLOCK);
        assert_eq!(found[0].line, 3);
    }

    #[test]
    fn pragma_without_reason_is_a_finding_and_suppresses_nothing() {
        let src = "// simlint: allow(no-wall-clock)\n\
                   let t = std::time::Instant::now();\n";
        let found = lint_src("src/x.rs", src);
        let rules_hit: Vec<&str> = found.iter().map(|f| f.rule).collect();
        assert!(rules_hit.contains(&RULE_PRAGMA), "{rules_hit:?}");
        assert!(rules_hit.contains(&rules::RULE_NO_WALL_CLOCK), "{rules_hit:?}");
    }

    #[test]
    fn pragma_with_unknown_rule_is_a_finding() {
        let found = lint_src("src/x.rs", "// simlint: allow(no-such-rule) -- typo\n");
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, RULE_PRAGMA);
        assert!(found[0].message.contains("no-such-rule"), "{}", found[0].message);
    }

    #[test]
    fn pragma_suppresses_only_the_named_rule() {
        let src = "// simlint: allow(no-stray-threads) -- wrong rule named\n\
                   let t = std::time::Instant::now();\n";
        let found = lint_src("src/x.rs", src);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, rules::RULE_NO_WALL_CLOCK);
    }

    #[test]
    fn multi_rule_pragma_parses() {
        let names =
            parse_pragma("allow(no-wall-clock, no-stray-threads) -- harness does both").unwrap();
        assert_eq!(names.len(), 2);
    }

    #[test]
    fn report_renders_sorted_text_and_json() {
        let report = LintReport {
            findings: vec![
                Finding {
                    rule: rules::RULE_NO_WALL_CLOCK,
                    path: "src/a.rs".into(),
                    line: 3,
                    message: "m1".into(),
                },
                Finding {
                    rule: rules::RULE_NO_MAP_ITERATION,
                    path: "src/b.rs".into(),
                    line: 9,
                    message: "m2".into(),
                },
            ],
            files_scanned: 2,
        };
        let text = report.render_text();
        assert!(text.contains("src/a.rs:3: [no-wall-clock] m1"), "{text}");
        assert!(text.contains("2 finding(s) in 2 files"), "{text}");
        let json = report.to_json();
        assert_eq!(json.str_or("kind", ""), "lint-report");
        let v = crate::experiments::OUTPUT_SCHEMA_VERSION;
        assert_eq!(json.usize_or("schema_version", 0), v);
        assert!(!json.bool_or("clean", true));
        assert_eq!(json.get("findings").and_then(Value::as_arr).unwrap().len(), 2);
    }

    #[test]
    fn clean_report_says_clean() {
        let report = LintReport { findings: Vec::new(), files_scanned: 7 };
        assert!(report.is_clean());
        assert!(report.render_text().contains("clean — 7 files"));
        assert!(report.to_json().bool_or("clean", false));
    }
}
