//! The `simlint` rule set: one function per named rule, all operating
//! on the comment-free token stream from [`super::lexer`].
//!
//! Every rule here guards a determinism or schema invariant the repo's
//! byte-identity contracts depend on (same sweep report at any
//! `--threads`, shard count, queue kind, or resume point — see
//! `docs/static-analysis.md` for the full rationale per rule):
//!
//! * [`no-float-partial-cmp`](RULE_NO_FLOAT_PARTIAL_CMP) — float
//!   orderings must use `total_cmp`; `partial_cmp(..).unwrap()` panics
//!   on the first NaN and `max_by`/`min_by` silently misorder.
//! * [`no-map-iteration`](RULE_NO_MAP_ITERATION) — iterating a
//!   `HashMap`/`HashSet` observes the randomized hash order; keyed
//!   lookup stays allowed (`cpu/package.rs::task_core` is the model).
//! * [`no-wall-clock`](RULE_NO_WALL_CLOCK) — `Instant::now` /
//!   `SystemTime::now` only in the benchmarking/serving layers.
//! * [`no-stray-threads`](RULE_NO_STRAY_THREADS) — thread/process
//!   spawning only in the sanctioned concurrency layer.
//! * [`schema-version-sync`](RULE_SCHEMA_VERSION_SYNC) — emitters must
//!   stamp `experiments::OUTPUT_SCHEMA_VERSION`, never a numeric
//!   literal, and `docs/output-schemas.md` must describe the current
//!   version.
//!
//! Rules are deliberately token-pattern based (not type-aware): they
//! trade a small false-positive surface for zero dependencies, and the
//! pragma escape hatch (`// simlint: allow(<rule>) -- <reason>`)
//! documents any intentional exception in place.

use std::collections::BTreeSet;

use super::lexer::{Tok, TokKind};
use super::Finding;

/// Rule name: float orderings must use `total_cmp`.
pub const RULE_NO_FLOAT_PARTIAL_CMP: &str = "no-float-partial-cmp";
/// Rule name: no `HashMap`/`HashSet` iteration outside `serving/`.
pub const RULE_NO_MAP_ITERATION: &str = "no-map-iteration";
/// Rule name: no wall-clock reads outside the allowlist.
pub const RULE_NO_WALL_CLOCK: &str = "no-wall-clock";
/// Rule name: no thread/process spawns outside the concurrency layer.
pub const RULE_NO_STRAY_THREADS: &str = "no-stray-threads";
/// Rule name: `schema_version` stamps must come from the constant.
pub const RULE_SCHEMA_VERSION_SYNC: &str = "schema-version-sync";

/// Every rule a pragma may name, in report order.
pub const RULE_NAMES: &[&str] = &[
    RULE_NO_FLOAT_PARTIAL_CMP,
    RULE_NO_MAP_ITERATION,
    RULE_NO_WALL_CLOCK,
    RULE_NO_STRAY_THREADS,
    RULE_SCHEMA_VERSION_SYNC,
];

/// Files (matched by `/`-suffix) where wall-clock reads are sanctioned:
/// the micro-bench harness, the perf-matrix harness, the subprocess
/// layer, and the CLI launcher (bench date stamp + simulate wall-time
/// stamp). `serving/` is sanctioned as a directory — the live serving
/// stack is wall-clock by nature.
const WALL_CLOCK_FILES: &[&str] =
    &["util/bench.rs", "util/proc.rs", "experiments/bench.rs", "main.rs"];
const WALL_CLOCK_DIRS: &[&str] = &["serving"];

/// Files/dirs where spawning is sanctioned: the scoped worker pool, the
/// subprocess pipe readers, and the serving worker thread. Everything
/// else must route concurrency through these.
const THREAD_FILES: &[&str] = &["util/pool.rs", "util/proc.rs"];
const THREAD_DIRS: &[&str] = &["serving"];

/// Dirs exempt from the map-iteration rule: the live serving stack is
/// not part of any byte-identical result path.
const MAP_ITER_EXEMPT_DIRS: &[&str] = &["serving"];

/// Map types whose iteration order is seeded per process.
const HASH_CONTAINERS: &[&str] = &["HashMap", "HashSet"];

/// Methods that observe a container's iteration order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// An `OUTPUT_SCHEMA_VERSION: usize = N` definition found while
/// scanning (normally in `experiments/mod.rs`); drives the docs half of
/// the `schema-version-sync` rule.
#[derive(Clone, Debug)]
pub struct SchemaDef {
    pub path: String,
    pub line: usize,
    pub version: usize,
}

/// True when `rel` *is* `name` or ends with `/name` (component-exact,
/// so `main.rs` never matches `domain.rs`).
fn is_file(rel: &str, name: &str) -> bool {
    rel == name || rel.strip_suffix(name).is_some_and(|head| head.ends_with('/'))
}

/// True when any *directory* component of `rel` equals `dir`.
fn in_dir(rel: &str, dir: &str) -> bool {
    let mut parts: Vec<&str> = rel.split('/').collect();
    parts.pop(); // the file name is not a directory component
    parts.iter().any(|p| *p == dir)
}

fn allowlisted(rel: &str, files: &[&str], dirs: &[&str]) -> bool {
    files.iter().any(|f| is_file(rel, f)) || dirs.iter().any(|d| in_dir(rel, d))
}

/// The comment-free view the rules pattern-match over.
struct Code<'a> {
    toks: Vec<&'a Tok>,
}

impl<'a> Code<'a> {
    fn new(toks: &'a [Tok]) -> Code<'a> {
        Code {
            toks: toks
                .iter()
                .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
                .collect(),
        }
    }

    fn len(&self) -> usize {
        self.toks.len()
    }

    fn kind(&self, i: usize) -> Option<TokKind> {
        self.toks.get(i).map(|t| t.kind)
    }

    fn is_ident(&self, i: usize, s: &str) -> bool {
        self.toks.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == s)
    }

    fn is_punct(&self, i: usize, s: &str) -> bool {
        self.toks.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == s)
    }

    fn ident_text(&self, i: usize) -> Option<&str> {
        self.toks.get(i).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
    }

    fn line(&self, i: usize) -> usize {
        self.toks[i].line
    }
}

/// Run every rule over one file's token stream. Returns the findings
/// (pragma suppression is applied by the caller, which also sees the
/// comment tokens) plus any `OUTPUT_SCHEMA_VERSION` definition found.
pub fn check_file(rel: &str, toks: &[Tok]) -> (Vec<Finding>, Option<SchemaDef>) {
    let code = Code::new(toks);
    let mut out = Vec::new();
    no_float_partial_cmp(rel, &code, &mut out);
    no_map_iteration(rel, &code, &mut out);
    no_wall_clock(rel, &code, &mut out);
    no_stray_threads(rel, &code, &mut out);
    let def = schema_version_sync(rel, &code, &mut out);
    (out, def)
}

fn finding(rule: &'static str, rel: &str, line: usize, message: String) -> Finding {
    Finding { rule, path: rel.to_string(), line, message }
}

/// (a) `no-float-partial-cmp` — any *call* of `partial_cmp` (`.`- or
/// `::`-qualified). A `fn partial_cmp` trait-impl definition is not a
/// call and is never flagged.
fn no_float_partial_cmp(rel: &str, code: &Code, out: &mut Vec<Finding>) {
    for i in 1..code.len() {
        if code.is_ident(i, "partial_cmp")
            && (code.is_punct(i - 1, ".") || code.is_punct(i - 1, ":"))
        {
            let msg = "partial_cmp call: on floats this panics (`.unwrap()`) or misorders \
                       (`max_by`/`min_by`) on NaN — order with `total_cmp` instead (see \
                       util/stats.rs for the NaN-safety rules)";
            out.push(finding(RULE_NO_FLOAT_PARTIAL_CMP, rel, code.line(i), msg.to_string()));
        }
    }
}

/// (b) `no-map-iteration` — collect the names declared or initialized
/// as `HashMap`/`HashSet` in this file, then flag any order-observing
/// use of them: `name.iter()`-style methods and `for … in [&][self.]name`.
/// Keyed access (`get`/`insert`/`remove`/`len`/`contains_key`) is
/// untouched, and `BTreeMap`/`BTreeSet` (deterministic order) never
/// match.
fn no_map_iteration(rel: &str, code: &Code, out: &mut Vec<Finding>) {
    if allowlisted(rel, &[], MAP_ITER_EXEMPT_DIRS) {
        return;
    }
    let mut maps: BTreeSet<&str> = BTreeSet::new();
    for i in 0..code.len() {
        let Some(name) = code.ident_text(i) else { continue };
        if !HASH_CONTAINERS.contains(&name) {
            continue;
        }
        // Walk back over a `path::to::` prefix to the start of the type.
        let mut j = i;
        while j >= 3
            && code.is_punct(j - 1, ":")
            && code.is_punct(j - 2, ":")
            && code.kind(j - 3) == Some(TokKind::Ident)
        {
            j -= 3;
        }
        // `binder: HashMap<..>` (field, let-annotation, or parameter).
        if j >= 2 && code.is_punct(j - 1, ":") && !code.is_punct(j - 2, ":") {
            if let Some(binder) = code.ident_text(j - 2) {
                maps.insert(binder);
            }
        }
        // `binder = HashMap::new()` (un-annotated let / assignment).
        if j >= 2 && code.is_punct(j - 1, "=") && !code.is_punct(j - 2, "=") {
            if let Some(binder) = code.ident_text(j - 2) {
                maps.insert(binder);
            }
        }
    }
    if maps.is_empty() {
        return;
    }
    for i in 0..code.len() {
        let Some(name) = code.ident_text(i) else { continue };
        if !maps.contains(name) {
            continue;
        }
        // `name.iter()` / `name.keys()` / …
        if code.is_punct(i + 1, ".") && code.is_punct(i + 3, "(") {
            if let Some(m) = code.ident_text(i + 2) {
                if ITER_METHODS.contains(&m) {
                    out.push(finding(
                        RULE_NO_MAP_ITERATION,
                        rel,
                        code.line(i),
                        format!(
                            "`{name}.{m}()` iterates a randomized-order hash container; \
                             hash-order iteration breaks byte-identical reports — use keyed \
                             lookup, or a BTreeMap/sorted Vec if iteration is required"
                        ),
                    ));
                }
            }
        }
        // `for pat in [& [mut]] [self.]name {` — the loop body brace
        // directly follows the map name.
        if code.is_punct(i + 1, "{") && i > 0 {
            let mut k = i - 1;
            while k > 0
                && (code.is_punct(k, ".")
                    || code.is_punct(k, "&")
                    || code.is_ident(k, "self")
                    || code.is_ident(k, "mut"))
            {
                k -= 1;
            }
            if code.is_ident(k, "in") {
                out.push(finding(
                    RULE_NO_MAP_ITERATION,
                    rel,
                    code.line(i),
                    format!(
                        "`for … in {name}` iterates a randomized-order hash container; \
                         hash-order iteration breaks byte-identical reports — use keyed \
                         lookup, or a BTreeMap/sorted Vec if iteration is required"
                    ),
                ));
            }
        }
    }
}

/// (c) `no-wall-clock` — `Instant::now` / `SystemTime::now` outside the
/// allowlisted benchmarking/serving/launcher files. The simulator core
/// must be a pure function of the spec: wall time is stamped by timing
/// *callers*, never read inside `Cluster::run` or below.
fn no_wall_clock(rel: &str, code: &Code, out: &mut Vec<Finding>) {
    if allowlisted(rel, WALL_CLOCK_FILES, WALL_CLOCK_DIRS) {
        return;
    }
    for i in 0..code.len() {
        let Some(ty) = code.ident_text(i) else { continue };
        if (ty == "Instant" || ty == "SystemTime")
            && code.is_punct(i + 1, ":")
            && code.is_punct(i + 2, ":")
            && code.is_ident(i + 3, "now")
        {
            out.push(finding(
                RULE_NO_WALL_CLOCK,
                rel,
                code.line(i),
                format!(
                    "`{ty}::now()` outside the benchmarking/serving layer: results must be \
                     a function of the spec alone — time the call site instead and stamp \
                     the result (see cluster::Cluster::run's wall_time_s contract)"
                ),
            ));
        }
    }
}

/// (d) `no-stray-threads` — `.spawn(` / `::spawn(` calls and
/// `thread::scope` outside the sanctioned concurrency layer. Sweep
/// determinism relies on every worker funneling through `util/pool.rs`
/// (deterministic reassembly) or `util/proc.rs` (captured children);
/// an ad-hoc thread has no such contract.
fn no_stray_threads(rel: &str, code: &Code, out: &mut Vec<Finding>) {
    if allowlisted(rel, THREAD_FILES, THREAD_DIRS) {
        return;
    }
    for i in 1..code.len() {
        if code.is_ident(i, "spawn")
            && (code.is_punct(i - 1, ".") || code.is_punct(i - 1, ":"))
            && code.is_punct(i + 1, "(")
        {
            let msg = "thread/process spawn outside util/pool.rs, util/proc.rs, or serving/: \
                       route concurrency through the worker pool (deterministic reassembly) \
                       or the subprocess layer";
            out.push(finding(RULE_NO_STRAY_THREADS, rel, code.line(i), msg.to_string()));
        }
        if code.is_ident(i, "thread")
            && code.is_punct(i + 1, ":")
            && code.is_punct(i + 2, ":")
            && code.is_ident(i + 3, "scope")
        {
            let msg = "`thread::scope` outside util/pool.rs or util/proc.rs: scoped threads \
                       are the pool's implementation detail, not an application-level API \
                       here";
            out.push(finding(RULE_NO_STRAY_THREADS, rel, code.line(i), msg.to_string()));
        }
    }
}

/// (e) `schema-version-sync`, emitter half — a `"schema_version"` key
/// whose value is a *numeric literal* stamped via the repo's
/// `Value::obj` idiom (`N.into()`). Readers with integer defaults
/// (`usize_or("schema_version", 0)`) never match because the literal is
/// not followed by `.into`. Also extracts the
/// `OUTPUT_SCHEMA_VERSION: usize = N` definition for the docs half
/// (run by the caller once the whole tree is scanned).
fn schema_version_sync(rel: &str, code: &Code, out: &mut Vec<Finding>) -> Option<SchemaDef> {
    for i in 0..code.len() {
        let is_key = code
            .toks
            .get(i)
            .is_some_and(|t| t.kind == TokKind::Str && t.text == "schema_version");
        if !is_key {
            continue;
        }
        let end = (i + 9).min(code.len());
        for j in i + 1..end {
            if code.kind(j) == Some(TokKind::Number)
                && code.is_punct(j + 1, ".")
                && code.is_ident(j + 2, "into")
            {
                out.push(finding(
                    RULE_SCHEMA_VERSION_SYNC,
                    rel,
                    code.line(j),
                    format!(
                        "hard-coded schema_version {}: stamp \
                         `experiments::OUTPUT_SCHEMA_VERSION` so every output and \
                         docs/output-schemas.md move together",
                        code.toks[j].text
                    ),
                ));
            }
        }
    }
    let mut def = None;
    for i in 0..code.len() {
        if code.is_ident(i, "OUTPUT_SCHEMA_VERSION")
            && code.is_punct(i + 1, ":")
            && code.is_ident(i + 2, "usize")
            && code.is_punct(i + 3, "=")
            && code.kind(i + 4) == Some(TokKind::Number)
        {
            if let Ok(version) = code.toks[i + 4].text.parse::<usize>() {
                def = Some(SchemaDef { path: rel.to_string(), line: code.line(i), version });
            }
        }
    }
    def
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        check_file(rel, &lex(src)).0
    }

    fn rules_hit(rel: &str, src: &str) -> Vec<&'static str> {
        run(rel, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn partial_cmp_call_flagged_definition_not() {
        let hits = rules_hit("src/x.rs", "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());");
        assert_eq!(hits, [RULE_NO_FLOAT_PARTIAL_CMP]);
        let ok = "impl PartialOrd for T { fn partial_cmp(&self, o: &Self) -> Option<Ordering> \
                  { Some(self.cmp(o)) } }";
        assert!(rules_hit("src/x.rs", ok).is_empty());
    }

    #[test]
    fn partial_cmp_in_comment_or_string_ignored() {
        let src = "// a.partial_cmp(b).unwrap() would panic\n\
                   const HINT: &str = \"never a.partial_cmp(b) on floats\";";
        assert!(rules_hit("src/x.rs", src).is_empty());
    }

    #[test]
    fn map_iteration_flagged_keyed_lookup_not() {
        let bad = "struct S { m: HashMap<u64, usize> }\n\
                   impl S { fn f(&self) { for (k, v) in self.m.iter() {} } }";
        assert_eq!(rules_hit("src/x.rs", bad), [RULE_NO_MAP_ITERATION]);
        let ok = "struct S { m: HashMap<u64, usize> }\n\
                  impl S { fn f(&self, id: u64) -> Option<usize> { self.m.get(&id).copied() } }";
        assert!(rules_hit("src/x.rs", ok).is_empty());
    }

    #[test]
    fn map_for_loop_flagged_btree_not() {
        let bad = "fn f(seen: std::collections::HashSet<u64>) { for k in &seen {} }";
        assert_eq!(rules_hit("src/x.rs", bad), [RULE_NO_MAP_ITERATION]);
        let ok = "fn f(seen: std::collections::BTreeSet<u64>) { for k in &seen {} }";
        assert!(rules_hit("src/x.rs", ok).is_empty());
    }

    #[test]
    fn map_let_initializer_tracked() {
        let bad = "fn f() { let mut seen = HashSet::new(); for k in &seen {} }";
        assert_eq!(rules_hit("src/x.rs", bad), [RULE_NO_MAP_ITERATION]);
    }

    #[test]
    fn map_iteration_allowed_in_serving() {
        let src = "struct S { m: HashMap<u64, usize> }\n\
                   impl S { fn f(&self) { for v in self.m.values() {} } }";
        assert!(rules_hit("src/serving/x.rs", src).is_empty());
        assert_eq!(rules_hit("src/cluster/x.rs", src), [RULE_NO_MAP_ITERATION]);
    }

    #[test]
    fn wall_clock_flagged_outside_allowlist_only() {
        let src = "fn f() -> std::time::Instant { std::time::Instant::now() }";
        assert_eq!(rules_hit("src/cluster/mod.rs", src), [RULE_NO_WALL_CLOCK]);
        assert!(rules_hit("src/util/bench.rs", src).is_empty());
        assert!(rules_hit("src/serving/batcher.rs", src).is_empty());
        assert!(rules_hit("src/main.rs", src).is_empty());
        let sys = "fn f() { let _ = SystemTime::now(); }";
        assert_eq!(rules_hit("src/sim/mod.rs", sys), [RULE_NO_WALL_CLOCK]);
    }

    #[test]
    fn own_clock_named_now_is_not_wall_clock() {
        let src = "fn f(q: &Queue) -> f64 { q.now() }";
        assert!(rules_hit("src/sim/mod.rs", src).is_empty());
    }

    #[test]
    fn stray_spawn_flagged_spawn_task_not() {
        assert_eq!(
            rules_hit("src/x.rs", "fn f() { std::thread::spawn(|| {}); }"),
            [RULE_NO_STRAY_THREADS]
        );
        assert_eq!(
            rules_hit("src/x.rs", "fn f() { std::thread::scope(|s| {}); }"),
            [RULE_NO_STRAY_THREADS]
        );
        assert!(rules_hit("src/x.rs", "fn f(m: &mut M) { m.spawn_task(0); }").is_empty());
        let pool = "fn f() { std::thread::scope(|s| {}); }";
        assert!(rules_hit("src/util/pool.rs", pool).is_empty());
    }

    #[test]
    fn hard_coded_schema_version_flagged_constant_not() {
        let bad = r#"fn j() -> Value { Value::obj(vec![("schema_version", 5.into())]) }"#;
        assert_eq!(rules_hit("src/x.rs", bad), [RULE_SCHEMA_VERSION_SYNC]);
        let ok = r#"fn j() -> Value {
            Value::obj(vec![("schema_version", super::OUTPUT_SCHEMA_VERSION.into())])
        }"#;
        assert!(rules_hit("src/x.rs", ok).is_empty());
        // Readers with integer defaults are not emitters.
        let reader = r#"fn r(v: &Value) -> usize { v.usize_or("schema_version", 0) }"#;
        assert!(rules_hit("src/x.rs", reader).is_empty());
    }

    #[test]
    fn schema_def_extracted() {
        let src = "pub const OUTPUT_SCHEMA_VERSION: usize = 6;";
        let (hits, def) = check_file("src/experiments/mod.rs", &lex(src));
        assert!(hits.is_empty());
        let def = def.expect("definition found");
        assert_eq!(def.version, 6);
        assert_eq!(def.line, 1);
    }

    #[test]
    fn path_matching_is_component_exact() {
        assert!(is_file("src/main.rs", "main.rs"));
        assert!(!is_file("src/domain.rs", "main.rs"));
        assert!(in_dir("src/serving/batcher.rs", "serving"));
        assert!(!in_dir("src/serving.rs", "serving"), "file name is not a dir component");
    }
}
