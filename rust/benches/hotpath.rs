//! §Perf micro/macro benchmarks of the simulator hot path.
//!
//! Targets (DESIGN.md §Perf): ≥1M simulated CPU-task events/s end-to-end
//! single-thread; per-operation costs below that imply:
//!   pick_core (Alg. 1)         < ~200 ns on a 40-core working set
//!   dvth_step (NBTI recursion) < ~50 ns
//!   adjust (Alg. 2)            < ~2 µs on 40 cores
//!   event queue push+pop       < ~100 ns
//!
//! Run: `cargo bench --bench hotpath`

use carbon_sim::cluster::{Cluster, ClusterConfig};
use carbon_sim::cpu::{AgingOps, AgingParams, Core, CpuPackage, TemperatureModel};
use carbon_sim::policy::{by_name, CoreManager};
use carbon_sim::sim::{QueueKind, Scheduler, SchedulerImpl};
use carbon_sim::trace::azure::{AzureTraceGen, TraceParams, Workload};
use carbon_sim::util::bench::{bench, section};
use carbon_sim::util::rng::Rng;

fn pkg(n: usize) -> CpuPackage {
    CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
}

fn main() {
    section("L3 micro: NBTI recursion");
    let aging = AgingParams::paper_default();
    let adf = aging.adf(327.15, 1.0);
    let mut dvth = 0.0f64;
    bench("dvth_step (closed-form reference)", 0.5, || {
        dvth = aging.dvth_step(std::hint::black_box(dvth.min(0.1)), adf, 0.001);
    });
    // The production path: equivalent-stress-time advance (one
    // multiply-add, no transcendentals) + the lazy powf snapshot read.
    let ops = AgingOps::new(&aging, &TemperatureModel::paper_default());
    let mut core = Core::new(0, 2.6);
    let mut t = 0.0f64;
    bench("core advance (eq-time fast path)", 0.5, || {
        t += 0.001;
        core.advance(std::hint::black_box(t), &ops);
    });
    bench("dvth snapshot (lazy powf read)", 0.5, || {
        std::hint::black_box(core.dvth(&ops));
    });

    section("L3 micro: SoA batch advance");
    for n in [40usize, 80] {
        let mut cpu = pkg(n);
        for t in 0..(n as u64 / 2) {
            cpu.assign(t as usize * 2, t, 0.0);
        }
        let mut tb = 0.0f64;
        bench(&format!("advance_all ({n} cores)"), 0.5, || {
            tb += 0.001;
            cpu.advance_all(std::hint::black_box(tb));
        });
    }

    section("L3 micro: policy decisions (40-core CPU, half loaded)");
    for pol in ["proposed", "linux", "least-aged"] {
        let mut mgr = CoreManager::new(pkg(40), by_name(pol).unwrap(), Rng::new(1));
        for t in 0..20u64 {
            mgr.start_task(t, 0.0);
        }
        let mut next = 100u64;
        let mut now = 1.0;
        bench(&format!("start+finish task [{pol}]"), 0.5, || {
            now += 0.001;
            mgr.start_task(next, now);
            mgr.finish_task(next, now + 0.0005);
            next += 1;
        });
    }

    section("L3 micro: Selective Core Idling (Alg. 2)");
    let mut mgr = CoreManager::new(pkg(40), by_name("proposed").unwrap(), Rng::new(1));
    for t in 0..10u64 {
        mgr.start_task(t, 0.0);
    }
    let mut now = 1.0;
    bench("adjust (40 cores)", 0.5, || {
        now += 1.0;
        mgr.adjust(now);
    });
    let mut mgr80 = CoreManager::new(pkg(80), by_name("proposed").unwrap(), Rng::new(1));
    let mut now80 = 1.0;
    bench("adjust (80 cores)", 0.5, || {
        now80 += 1.0;
        mgr80.adjust(now80);
    });
    // The coalesced-tick fast path: a machine with no mutations since the
    // last tick costs one dirty-bit branch, not an Algorithm 2 pass.
    let mut mgr_skip = CoreManager::new(pkg(40), by_name("proposed").unwrap(), Rng::new(1));
    for t in 0..10u64 {
        mgr_skip.start_task(t, 0.0);
    }
    let mut now_skip = 1.0;
    for _ in 0..64 {
        if !mgr_skip.adjust_tick(now_skip) {
            break;
        }
        now_skip += 0.25;
    }
    bench("adjust_tick (clean skip, 40 cores)", 0.5, || {
        now_skip += 0.25;
        std::hint::black_box(mgr_skip.adjust_tick(now_skip));
    });

    section("L3 micro: event queue (heap vs calendar)");
    // Steady state at two in-flight populations: each iteration pushes one
    // event and pops the earliest, so the queue size stays pinned. Delays
    // cycle over ~7 s of sim time with repeats, giving both spread and
    // same-timestamp collisions; the clock advances on every pop, so the
    // calendar wheel rotates at its production rate.
    for kind in [QueueKind::Heap, QueueKind::Calendar] {
        for n in [1_000u64, 100_000] {
            let mut q: SchedulerImpl<u64> = SchedulerImpl::new(kind);
            for i in 0..n {
                q.push_in(0.001 + (i % 7_000) as f64 * 1e-3, i);
            }
            let mut i = n;
            bench(&format!("push+pop [{:<8} @ {n:>6} in-flight]", kind.name()), 0.5, || {
                q.push_in(0.001 + (i % 7_000) as f64 * 1e-3, i);
                std::hint::black_box(q.pop());
                i += 1;
            });
        }
    }

    section("L3 macro: end-to-end simulator throughput");
    for pol in ["proposed", "linux"] {
        let trace = AzureTraceGen::new(TraceParams {
            rate_rps: 80.0,
            duration_s: 30.0,
            workload: Workload::Mixed,
            seed: 5,
        })
        .generate();
        let cfg = ClusterConfig { policy: pol.into(), ..ClusterConfig::default() };
        let t0 = std::time::Instant::now();
        let result = Cluster::new(cfg).run(&trace);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "bench sim 22x40 @80rps x30s [{pol:<10}] {:>12.0} events/s  ({} events, {:.2}s wall)",
            result.events_processed as f64 / wall,
            result.events_processed,
            wall
        );
    }

    section("PJRT macro: aging_step artifact (if built)");
    match pjrt_bench() {
        Ok(()) => {}
        Err(e) => println!("skipped: {e:#} (run `make artifacts`)"),
    }
}

fn pjrt_bench() -> anyhow::Result<()> {
    use carbon_sim::runtime::{AgingStepPjrt, Runtime};
    let dir = Runtime::default_artifacts_dir();
    anyhow::ensure!(Runtime::artifacts_available(&dir), "artifacts not found in {dir:?}");
    let rt = Runtime::cpu(dir)?;
    let step = AgingStepPjrt::load(&rt)?;
    let n = step.machines * step.cores;
    let dvth = vec![0.01f32; n];
    let adf = vec![0.005f32; n];
    let tau = vec![100f32; n];
    let f0 = vec![2.6f32; n];
    bench(&format!("aging_step PJRT ({}x{})", step.machines, step.cores), 1.0, || {
        step.step(&dvth, &adf, &tau, &f0).expect("step");
    });
    Ok(())
}
