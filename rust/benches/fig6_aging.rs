//! Fig. 6 reproduction bench: CV-of-frequency and mean-degradation
//! management performance across throughputs, policies, and VM core
//! counts (paper §6.2, Fig. 6a/6b).
//!
//! Run: `cargo bench --bench fig6_aging`
//! Scale via env: CARBON_SIM_BENCH_DURATION (s, default 120),
//! CARBON_SIM_BENCH_SCALE=smoke for a quick pass.

use carbon_sim::experiments::{fig6, run_matrix, Scale};

fn main() {
    let mut scale = match std::env::var("CARBON_SIM_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::smoke(),
        _ => Scale::paper(),
    };
    if let Ok(d) = std::env::var("CARBON_SIM_BENCH_DURATION") {
        scale.duration_s = d.parse().expect("numeric duration");
    }
    let t0 = std::time::Instant::now();
    let cells = run_matrix(&scale);
    let rows = fig6::rows(&cells, 2.6);
    fig6::print(&rows);
    let violations = fig6::check_shape(&rows);
    let events: u64 = cells.iter().flat_map(|c| c.results.iter()).map(|r| r.events_processed).sum();
    println!(
        "\nfig6: {} runs, {events} events, {:.1}s wall",
        cells.len() * 3,
        t0.elapsed().as_secs_f64()
    );
    if violations.is_empty() {
        println!("fig6 shape: OK (proposed > baselines on freq perf; least-aged >= linux on CV)");
    } else {
        println!("fig6 shape VIOLATIONS:");
        for v in &violations {
            println!("  {v}");
        }
        std::process::exit(1);
    }
}
