//! Fig. 7 reproduction bench: estimated yearly cluster CPU-embodied
//! carbon per policy/throughput, via the lifetime-extension model
//! (3-year refresh, 278.3 kgCO₂eq per server CPU complex).
//!
//! Paper headline: proposed cuts yearly emissions 37.67 % @p99 of mean
//! frequency degradation (49.01 % @p50). Shape target: proposed shows a
//! large reduction; least-aged ≈ linux.
//!
//! Run: `cargo bench --bench fig7_carbon`

use carbon_sim::carbon::EmbodiedModel;
use carbon_sim::experiments::{fig7, run_matrix, Scale};

fn main() {
    let mut scale = match std::env::var("CARBON_SIM_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::smoke(),
        _ => Scale::paper(),
    };
    if let Ok(d) = std::env::var("CARBON_SIM_BENCH_DURATION") {
        scale.duration_s = d.parse().expect("numeric duration");
    }
    let t0 = std::time::Instant::now();
    let cells = run_matrix(&scale);
    let rows = fig7::rows(&cells, &EmbodiedModel::paper_default());
    fig7::print(&rows);
    // Aggregate headline: mean reduction across the sweep for `proposed`.
    let reds: Vec<f64> =
        rows.iter().filter(|r| r.policy == "proposed").map(|r| r.reduction_pct_p99).collect();
    let reds50: Vec<f64> =
        rows.iter().filter(|r| r.policy == "proposed").map(|r| r.reduction_pct_p50).collect();
    println!(
        "\nheadline: proposed mean reduction {:.2}% @p99 (paper: 37.67%), {:.2}% @p50 (paper: 49.01%)",
        carbon_sim::util::stats::mean(&reds),
        carbon_sim::util::stats::mean(&reds50),
    );
    println!("fig7 wall: {:.1}s", t0.elapsed().as_secs_f64());
    let violations = fig7::check_shape(&rows);
    if violations.is_empty() {
        println!("fig7 shape: OK (proposed large reduction; least-aged minimal)");
    } else {
        println!("fig7 shape VIOLATIONS:");
        for v in &violations {
            println!("  {v}");
        }
        std::process::exit(1);
    }
}
