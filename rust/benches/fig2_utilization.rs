//! Fig. 2 reproduction bench: per-machine concurrent inference-task
//! distributions (the motivating observation study — O1 low means,
//! O2 occasional bursts).
//!
//! Run: `cargo bench --bench fig2_utilization`

use carbon_sim::experiments::{fig2, Scale};

fn main() {
    let mut scale = match std::env::var("CARBON_SIM_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::smoke(),
        _ => Scale::paper(),
    };
    if let Ok(d) = std::env::var("CARBON_SIM_BENCH_DURATION") {
        scale.duration_s = d.parse().expect("numeric duration");
    }
    let cores = scale.core_counts[0];
    let t0 = std::time::Instant::now();
    let levels = fig2::run(&scale, cores);
    fig2::print(&levels);
    println!("\nfig2 wall: {:.1}s", t0.elapsed().as_secs_f64());
    let violations = fig2::check_shape(&levels, cores);
    if violations.is_empty() {
        println!("fig2 shape: OK (O1 underutilized means, O2 bursts present)");
    } else {
        println!("fig2 shape VIOLATIONS:");
        for v in &violations {
            println!("  {v}");
        }
        std::process::exit(1);
    }
}
