//! Fig. 4 / Table 1 reproduction bench: core temperatures of a 12-core
//! CPU when 6 cores toggle into C6 mid-experiment. Plateaus must match
//! the Table 1 steady states (54 / 51.08 / 48 °C).
//!
//! Run: `cargo bench --bench fig4_temperature`

use carbon_sim::experiments::fig4;

fn main() {
    let r = fig4::run(600.0, 120.0, 420.0, 1.0);
    fig4::print(&r);
    // Assert the plateaus.
    let during = r.points.iter().find(|p| (p.t_s - 400.0).abs() < 0.5).unwrap();
    let after = r.points.last().unwrap();
    assert!((during.toggled_group_c - 48.0).abs() < 0.1, "C6 plateau");
    assert!((during.active_group_c - 54.0).abs() < 0.1, "C0 allocated plateau");
    assert!((after.toggled_group_c - 54.0).abs() < 0.25, "rewake plateau");
    println!("\nfig4 shape: OK (plateaus at Table 1 values: 54 / 48 °C, smooth transients)");
}
