//! Fig. 1 reproduction bench: A100x4 inference-server yearly carbon by
//! grid energy source — the motivation that CPU embodied carbon dominates
//! under renewables.
//!
//! Run: `cargo bench --bench fig1_carbon_intensity`

use carbon_sim::carbon::ServerPowerModel;
use carbon_sim::experiments::fig1;

fn main() {
    let rows = fig1::run(&ServerPowerModel::a100x4());
    fig1::print(&rows);
    let wind = rows.iter().find(|r| r.source == "wind").unwrap();
    let coal = rows.iter().find(|r| r.source == "coal").unwrap();
    println!(
        "\nshape: cpu-embodied share {:.1}% under wind vs {:.1}% under coal",
        wind.cpu_share * 100.0,
        coal.cpu_share * 100.0
    );
    assert!(wind.cpu_share > 0.25 && coal.cpu_share < 0.05, "fig1 shape violated");
    println!("fig1 shape: OK (embodied dominates under low-carbon energy)");
}
