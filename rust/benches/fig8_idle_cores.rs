//! Fig. 8 reproduction bench: normalized idle-core distributions per
//! policy (positive = underutilization, negative = oversubscription).
//!
//! Shape targets: baselines pile up near +1.0; proposed sits near 0 with
//! ≥77 % lower p90 underutilization and oversubscription bounded at −0.1.
//!
//! Run: `cargo bench --bench fig8_idle_cores`

use carbon_sim::experiments::{fig8, run_matrix, Scale};

fn main() {
    let mut scale = match std::env::var("CARBON_SIM_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::smoke(),
        _ => Scale::paper(),
    };
    if let Ok(d) = std::env::var("CARBON_SIM_BENCH_DURATION") {
        scale.duration_s = d.parse().expect("numeric duration");
    }
    let t0 = std::time::Instant::now();
    let cells = run_matrix(&scale);
    let rows = fig8::rows(&cells);
    fig8::print(&rows);
    // Underutilization-reduction headline (p90 vs linux, averaged).
    let mut reductions = Vec::new();
    for r in rows.iter().filter(|r| r.policy == "proposed") {
        let linux = rows
            .iter()
            .find(|x| x.cores == r.cores && x.rate == r.rate && x.policy == "linux")
            .unwrap();
        if linux.idle.p90 > 0.0 {
            reductions.push((1.0 - r.idle.p90 / linux.idle.p90) * 100.0);
        }
    }
    println!(
        "\nheadline: proposed reduces p90 underutilization by {:.1}% (paper: ≥77%)",
        carbon_sim::util::stats::mean(&reductions)
    );
    println!("fig8 wall: {:.1}s", t0.elapsed().as_secs_f64());
    let violations = fig8::check_shape(&rows);
    if violations.is_empty() {
        println!("fig8 shape: OK");
    } else {
        println!("fig8 shape VIOLATIONS:");
        for v in &violations {
            println!("  {v}");
        }
        std::process::exit(1);
    }
}
