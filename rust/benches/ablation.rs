//! Ablation bench: how much of the proposed technique's gain comes from
//! each mechanism?
//!
//!   linux             — age-oblivious placement, no idling (baseline)
//!   least-aged        — even-out only, via executed-work estimate
//!   proposed-taskmap  — Algorithm 1 only (idle-score even-out, no C6)
//!   proposed          — Algorithm 1 + Algorithm 2 (even-out + age halting)
//!
//! Expected: Alg. 1 alone ≈ least-aged (even-out without halting barely
//! moves mean degradation); adding Selective Core Idling delivers the
//! carbon headline — supporting the paper's Table 3 claim that *dynamic
//! age-halting* is the distinguishing capability.
//!
//! Run: `cargo bench --bench ablation`

use carbon_sim::carbon::EmbodiedModel;
use carbon_sim::cluster::Cluster;
use carbon_sim::experiments::Scale;
use carbon_sim::util::stats::Summary;

fn main() {
    let mut scale = match std::env::var("CARBON_SIM_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::smoke(),
        _ => Scale::paper(),
    };
    if let Ok(d) = std::env::var("CARBON_SIM_BENCH_DURATION") {
        scale.duration_s = d.parse().expect("numeric duration");
    }
    let variants =
        ["linux", "least-aged", "proposed-taskmap", "proposed", "proposed-telemetry"];
    let cores = scale.core_counts[0];
    let rate = scale.rates[scale.rates.len() / 2];
    let trace = scale.trace(rate);
    let f0 = scale.config(cores, "linux").sample_f0();
    let model = EmbodiedModel::paper_default();

    println!("ablation @ {rate} rps, {cores}-core VMs, {}s trace", scale.duration_s);
    println!(
        "{:<18} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "variant", "fred_p50_mhz", "cv_p50", "red%@p50", "idle_p90", "oversub_p1"
    );
    let mut linux_fred_p50 = 0.0;
    let mut rows = Vec::new();
    for pol in variants {
        let mut cfg = scale.config(cores, pol);
        cfg.f0_override = Some(f0.clone());
        let r = Cluster::new(cfg).run(&trace);
        let fred = Summary::of(&r.mean_fred_per_machine());
        let cv = Summary::of(&r.freq_cv_per_machine());
        let idle = Summary::of(&r.pooled_idle_samples());
        if pol == "linux" {
            linux_fred_p50 = fred.p50;
        }
        let red = model.reduction_pct(linux_fred_p50, fred.p50);
        println!(
            "{:<18} {:>12.4} {:>12.6} {:>12.2} {:>12.3} {:>12.3}",
            pol,
            fred.p50 * 1e3,
            cv.p50,
            red,
            idle.p90,
            idle.p1
        );
        rows.push((pol, fred.p50, cv.p50, red));
    }
    // Shape assertions.
    let get = |p: &str| rows.iter().find(|r| r.0 == p).unwrap().clone();
    let (_, fred_tm, cv_tm, red_tm) = get("proposed-taskmap");
    let (_, fred_full, _, red_full) = get("proposed");
    let (_, fred_linux, cv_linux, _) = get("linux");
    assert!(
        red_full > red_tm + 10.0,
        "age halting must dominate the carbon gain ({red_full:.1}% vs {red_tm:.1}%)"
    );
    assert!(fred_full < fred_tm && fred_tm <= fred_linux * 1.02);
    assert!(cv_tm <= cv_linux * 1.01, "Alg 1 must not worsen unevenness");
    println!("\nablation shape: OK (Alg 2's dynamic age-halting carries the carbon reduction)");
}
