//! Fig. 5 reproduction bench: the piecewise reaction function F(e) —
//! tan branch (slow, underutilization) vs arctan branch (fast,
//! oversubscription).
//!
//! Run: `cargo bench --bench fig5_reaction`

use carbon_sim::experiments::fig5;

fn main() {
    let pts = fig5::run(40);
    fig5::print(&pts);
    // Asymmetry + saturation checks.
    let at = |e: f64| {
        pts.iter().min_by(|a, b| (a.e - e).abs().total_cmp(&(b.e - e).abs())).unwrap().f
    };
    assert!(at(-0.2).abs() > at(0.2).abs(), "oversubscription branch must react faster");
    assert!(at(1.0) > 0.99 && at(-1.0) < -0.99, "saturation at ±1");
    println!("\nfig5 shape: OK (asymmetric piecewise tan/arctan)");
}
